"""Distributed (shard_map) chain engine + replicated-KV-cache collectives.
Run in subprocesses with emulated devices (jax pins device count at init).
"""
import pytest

from helpers import run_with_devices


@pytest.mark.slow
def test_chain_dist_write_read_roundtrip():
    out = run_with_devices("""
import jax, jax.numpy as jnp
from repro.core import ChainConfig, ChainDist, CLIENT_BASE
from repro.core.types import Msg, OP_READ, OP_WRITE

mesh = jax.make_mesh((4,), ("chain",))
cfg = ChainConfig(n_nodes=4, num_keys=16, num_versions=4, protocol="netcraq")
dist = ChainDist(cfg, mesh, axis="chain")
stores = dist.init_state()
roles = dist.full_roles()
pmap = dist.default_pmap()
B = 8
step = dist.make_step(B)
locks = dist.init_locks()

def inject(op, key, val, node):
    m = Msg.empty(B)
    m = jax.tree.map(lambda x: jnp.tile(x[None], (4,) + (1,)*x.ndim), m)
    return m._replace(
        op=m.op.at[node, 0].set(op), key=m.key.at[node, 0].set(key),
        value=m.value.at[node, 0, 0].set(val),
        src=m.src.at[node, 0].set(CLIENT_BASE+7),
        client=m.client.at[node, 0].set(CLIENT_BASE+7),
        qid=m.qid.at[node, 0].set(42), dst=m.dst.at[node, 0].set(node))

inbox = inject(OP_WRITE, 3, 99, 0)
for _ in range(8):
    stores, inbox, replies, locks = step(stores, inbox, roles, pmap, locks)
assert stores.values[:, 3, 0, 0].tolist() == [99]*4, stores.values[:, 3, 0, 0]
assert stores.pending[:, 3].tolist() == [0]*4

inbox = inject(OP_READ, 3, 0, 2)
stores, inbox, replies, locks = step(stores, inbox, roles, pmap, locks)
r = jax.device_get(replies)
live = r.op != 0
assert live.sum() == 1 and r.value[live][0, 0] == 99, r.value[live]
print("DIST_OK")
""")
    assert "DIST_OK" in out


@pytest.mark.slow
def test_chain_dist_serves_with_dead_node():
    """make_step consumes the CP's live role table: with node 1 spliced out
    the write path runs head 0 -> 2 -> tail 3 (the skip rides the fabric
    collective), the dead device neither stores nor ACKs, and reads keep
    serving - all without re-making the step function."""
    out = run_with_devices("""
import jax, jax.numpy as jnp
from repro.core import ChainConfig, ChainDist, Coordinator, CLIENT_BASE
from repro.core.types import Msg, OP_READ, OP_WRITE

mesh = jax.make_mesh((4,), ("chain",))
cfg = ChainConfig(n_nodes=4, num_keys=16, num_versions=4, protocol="netcraq")
dist = ChainDist(cfg, mesh, axis="chain")
stores = dist.init_state()
pmap = dist.default_pmap()
B = 8
step = dist.make_step(B)
locks = dist.init_locks()

def inject(op, key, val, node):
    m = Msg.empty(B)
    m = jax.tree.map(lambda x: jnp.tile(x[None], (4,) + (1,)*x.ndim), m)
    return m._replace(
        op=m.op.at[node, 0].set(op), key=m.key.at[node, 0].set(key),
        value=m.value.at[node, 0, 0].set(val),
        src=m.src.at[node, 0].set(CLIENT_BASE+7),
        client=m.client.at[node, 0].set(CLIENT_BASE+7),
        qid=m.qid.at[node, 0].set(42), dst=m.dst.at[node, 0].set(node))

co = Coordinator(cfg)
co.fail_node(0, 1)
roles = jax.tree.map(lambda x: x[0], co.roles_table())  # [n] leaves

inbox = inject(OP_WRITE, 3, 99, 0)
for _ in range(8):
    stores, inbox, replies, locks = step(stores, inbox, roles, pmap, locks)
assert stores.values[:, 3, 0, 0].tolist() == [99, 0, 99, 99], \\
    stores.values[:, 3, 0, 0]
assert stores.pending[:, 3].tolist() == [0]*4

inbox = inject(OP_READ, 3, 0, 2)
stores, inbox, replies, locks = step(stores, inbox, roles, pmap, locks)
r = jax.device_get(replies)
live = r.op != 0
assert live.sum() == 1 and r.value[live][0, 0] == 99, r.value[live]
print("DEAD_NODE_OK")
""")
    assert "DEAD_NODE_OK" in out


@pytest.mark.slow
def test_chain_dist_multichain_groups():
    """Two chains side by side on a (cgroup, chain) mesh: collectives stay
    scoped to each chain, writes/reads never leak across groups."""
    out = run_with_devices("""
import jax, jax.numpy as jnp
from repro.core import ChainConfig, ClusterConfig, ChainDist, CLIENT_BASE
from repro.core.types import Msg, OP_READ, OP_WRITE

mesh = jax.make_mesh((2, 4), ("cgroup", "chain"))
cfg = ChainConfig(n_nodes=4, num_keys=16, num_versions=4, protocol="netcraq")
dist = ChainDist(ClusterConfig(chain=cfg, n_chains=2), mesh,
                 axis="chain", group_axis="cgroup")
stores = dist.init_state()
roles = dist.full_roles()
pmap = dist.default_pmap()
B = 8
step = dist.make_step(B)
locks = dist.init_locks()

def inject(op, key, val, node, chain):
    m = Msg.empty(B)
    m = jax.tree.map(lambda x: jnp.tile(x[None, None], (2, 4) + (1,)*x.ndim), m)
    return m._replace(
        op=m.op.at[chain, node, 0].set(op),
        key=m.key.at[chain, node, 0].set(key),
        value=m.value.at[chain, node, 0, 0].set(val),
        src=m.src.at[chain, node, 0].set(CLIENT_BASE+7),
        client=m.client.at[chain, node, 0].set(CLIENT_BASE+7),
        qid=m.qid.at[chain, node, 0].set(42),
        dst=m.dst.at[chain, node, 0].set(node))

inbox = inject(OP_WRITE, 5, 123, 0, 1)
for _ in range(8):
    stores, inbox, replies, locks = step(stores, inbox, roles, pmap, locks)
assert stores.values[1, :, 5, 0, 0].tolist() == [123]*4, stores.values[1, :, 5, 0, 0]
assert stores.values[0, :, 5, 0, 0].tolist() == [0]*4   # chain 0 untouched
assert int(stores.pending.sum()) == 0

inbox = inject(OP_READ, 5, 0, 2, 1)
stores, inbox, replies, locks = step(stores, inbox, roles, pmap, locks)
r = jax.device_get(replies)
live = r.op != 0
assert live.sum() == 1 and r.value[live][0, 0] == 123, r.value[live]
print("GROUPS_OK")
""")
    assert "GROUPS_OK" in out


@pytest.mark.slow
def test_chain_dist_lock_stage():
    """The dist engine's replicated head lock stage: a PREPARE at the head
    acquires the lock and ACKs, a conflicting PREPARE NACKs, COMMIT lands
    the value and releases - the lock shard stays consistent (replicated)
    across devices without a collective write-back."""
    out = run_with_devices("""
import jax, jax.numpy as jnp
from repro.core import ChainConfig, ChainDist, CLIENT_BASE
from repro.core.types import (Msg, OP_PREPARE, OP_PREPARE_ACK,
                              OP_PREPARE_NACK, OP_COMMIT, OP_TXN_REPLY)

mesh = jax.make_mesh((4,), ("chain",))
cfg = ChainConfig(n_nodes=4, num_keys=16, num_versions=4, protocol="netcraq")
dist = ChainDist(cfg, mesh, axis="chain")
stores = dist.init_state()
roles = dist.full_roles()
pmap = dist.default_pmap()
B = 8
step = dist.make_step(B)
locks = dist.init_locks()

def inject(op, key, val, seq, client, slot=0, node=0):
    m = Msg.empty(B)
    m = jax.tree.map(lambda x: jnp.tile(x[None], (4,) + (1,)*x.ndim), m)
    return m._replace(
        op=m.op.at[node, slot].set(op), key=m.key.at[node, slot].set(key),
        value=m.value.at[node, slot, 0].set(val),
        seq=m.seq.at[node, slot].set(seq),
        src=m.src.at[node, slot].set(CLIENT_BASE+client),
        client=m.client.at[node, slot].set(CLIENT_BASE+client),
        qid=m.qid.at[node, slot].set(40+slot),
        dst=m.dst.at[node, slot].set(node))

# two PREPAREs for the same key in one batch: first wins, second NACKs
m1 = inject(OP_PREPARE, 3, 0, 7, 1, slot=0)
m2 = inject(OP_PREPARE, 3, 0, 8, 2, slot=1)
live2 = m2.op != 0
inbox = jax.tree.map(lambda a, b: jnp.where(
    live2.reshape(live2.shape + (1,)*(a.ndim - live2.ndim)), b, a), m1, m2)
stores, inbox, replies, locks = step(stores, inbox, roles, pmap, locks)
r = jax.device_get(replies)
ops = r.op[r.op != 0].tolist()
assert sorted(ops) == sorted([OP_PREPARE_ACK, OP_PREPARE_NACK]), ops
assert locks.holder[0, 3].tolist() == 7, locks.holder
assert locks.client[0, 3].tolist() == CLIENT_BASE + 1

# COMMIT releases the lock and the write propagates to every live node
inbox = inject(OP_COMMIT, 3, 99, 7, 1)
for _ in range(8):
    stores, inbox, replies, locks = step(stores, inbox, roles, pmap, locks)
assert locks.holder[0, 3].tolist() == -1, locks.holder
assert locks.version[0, 3].tolist() == 1
assert stores.values[:, 3, 0, 0].tolist() == [99]*4, stores.values[:, 3, 0, 0]
print("LOCK_STAGE_OK")
""")
    assert "LOCK_STAGE_OK" in out


@pytest.mark.slow
def test_chain_dist_telemetry_hist():
    """The dist engine's opt-in telemetry: ``make_step(B, telemetry=True)``
    threads a Telemetry operand through the shard_map step and scatters
    each device's reply batch into its latency histogram shard, clocked by
    the per-device ``ring_cursor`` step counter (the dist engine has no
    shared SimState.t).  The histogram totals must match the replies the
    host actually saw, per op class."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import ChainConfig, ChainDist, CLIENT_BASE
from repro.core.types import (Msg, OP_READ, OP_READ_REPLY, OP_WRITE,
                              OP_WRITE_REPLY, OPCLASS_READ, OPCLASS_WRITE)

mesh = jax.make_mesh((4,), ("chain",))
cfg = ChainConfig(n_nodes=4, num_keys=16, num_versions=4, protocol="netcraq")
dist = ChainDist(cfg, mesh, axis="chain")
stores = dist.init_state()
roles = dist.full_roles()
pmap = dist.default_pmap()
B = 8
step = dist.make_step(B, telemetry=True)
locks = dist.init_locks()
tel = dist.init_telemetry()

def inject(op, key, val, node, t):
    m = Msg.empty(B)
    m = jax.tree.map(lambda x: jnp.tile(x[None], (4,) + (1,)*x.ndim), m)
    return m._replace(
        op=m.op.at[node, 0].set(op), key=m.key.at[node, 0].set(key),
        value=m.value.at[node, 0, 0].set(val),
        src=m.src.at[node, 0].set(CLIENT_BASE+7),
        client=m.client.at[node, 0].set(CLIENT_BASE+7),
        qid=m.qid.at[node, 0].set(42), dst=m.dst.at[node, 0].set(node),
        t_inject=m.t_inject.at[node, 0].set(t))

seen_r = seen_w = 0
inbox = inject(OP_WRITE, 3, 99, 0, 0)
for _ in range(8):
    stores, inbox, replies, locks, tel = step(
        stores, inbox, roles, pmap, locks, tel)
    r = jax.device_get(replies)
    seen_r += int((r.op == OP_READ_REPLY).sum())
    seen_w += int((r.op == OP_WRITE_REPLY).sum())
inbox = inject(OP_READ, 3, 0, 2, 8)  # injected at clock 8
stores, inbox, replies, locks, tel = step(
    stores, inbox, roles, pmap, locks, tel)
r = jax.device_get(replies)
seen_r += int((r.op == OP_READ_REPLY).sum())
seen_w += int((r.op == OP_WRITE_REPLY).sum())
assert seen_r == 1 and seen_w == 1, (seen_r, seen_w)

hist = np.asarray(jax.device_get(tel.lat_hist))
flat = hist.reshape((-1,) + hist.shape[-2:]).sum(axis=0)  # [OPCLASS, BKT]
assert int(flat[OPCLASS_READ].sum()) == seen_r, flat
assert int(flat[OPCLASS_WRITE].sum()) == seen_w, flat
assert int(flat.sum()) == seen_r + seen_w, flat
# per-device step clock: one row per step on every device
assert np.asarray(jax.device_get(tel.ring_cursor)).tolist() == [9]*4
# the read completed in one step -> bucket 0; the write propagated the
# whole 4-node chain -> strictly slower
read_b = int(np.nonzero(flat[OPCLASS_READ])[0][0])
write_b = int(np.nonzero(flat[OPCLASS_WRITE])[0][0])
assert read_b == 0 and write_b >= read_b, (read_b, write_b)
print("DIST_TEL_OK")
""")
    assert "DIST_TEL_OK" in out


@pytest.mark.slow
def test_replicated_kv_cache_protocols():
    out = run_with_devices("""
import jax, jax.numpy as jnp, functools
from jax.sharding import PartitionSpec as P
from repro.serve import kv_cache as KV
from repro.distributed.shard import shard_map

n = 4
mesh = jax.make_mesh((n,), ("chain",))

def craq_body(kv_new, seq):
    own, replica, ack = KV.netcraq_append(kv_new, seq, axis="chain", n=n)
    return own, replica, ack

def cr_body(page, seq):
    fetched = KV.netchain_read(page, axis="chain", n=n)
    committed, ack = KV.netchain_append(page, seq, axis="chain", n=n)
    return fetched, committed, ack

kv = jnp.arange(n*8, dtype=jnp.float32).reshape(n, 8)   # distinct per node
seqs = jnp.arange(n, dtype=jnp.int32) + 10

craq = jax.jit(shard_map(craq_body, mesh=mesh,
    in_specs=(P("chain"), P("chain")), out_specs=(P("chain"), P("chain"), P("chain"))))
own, replica, ack = craq(kv, seqs)
# node i>0 stores node i-1's page as the replica copy
assert jnp.allclose(replica[1:], kv[:-1]), replica
assert jnp.allclose(replica[0], kv[0])
# tail's seq broadcast to everyone
assert ack.tolist() == [13]*n, ack

cr = jax.jit(shard_map(cr_body, mesh=mesh,
    in_specs=(P("chain"), P("chain")), out_specs=(P("chain"), P("chain"), P("chain"))))
fetched, committed, ack2 = cr(kv, seqs)
# CR read: every node receives the TAIL's page
assert jnp.allclose(fetched, jnp.tile(kv[-1], (n, 1))), fetched
# CR write: the tail ends holding the head's page after n-1 hops
assert jnp.allclose(committed[-1], kv[0]), committed[-1]
print("KV_OK")
""")
    assert "KV_OK" in out


@pytest.mark.slow
def test_failover_select():
    out = run_with_devices("""
import jax.numpy as jnp
from repro.serve.kv_cache import failover_select
local = jnp.zeros((4, 3))
replica = jnp.ones((4, 3))
failed = jnp.asarray([True, False, True, False])
out = failover_select(local, replica, failed)
assert out[:, 0].tolist() == [1., 0., 1., 0.]
print("FO_OK")
""", n_devices=1)
    assert "FO_OK" in out
