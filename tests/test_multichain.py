"""Multi-chain data-plane semantics.

The cluster partitions the global key space across C virtual chains
(disjoint stores, disjoint routing fabrics).  These tests pin down:

* partition totality - every global key is owned by exactly one chain and
  the (chain, local) coordinates round-trip;
* per-chain linearizability/isolation - a write to chain c is never
  visible via chain c' (neither in replies nor in stores);
* C=1 seed equivalence - a single-chain cluster reproduces the legacy
  single-chain engine's schedule and exact packet/byte/reply counts;
* throughput scaling - C chains at fixed per-chain load deliver ~C x the
  aggregate replies (the paper's multi-node headline, acceptance >= 3x at
  C=4);
* control-plane surgery on a non-zero chain of the running [C, n, ...]
  store pytree.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ChainConfig,
    ChainSim,
    ClusterConfig,
    Coordinator,
    WorkloadConfig,
    make_schedule,
    route_stream,
)
from repro.core.types import (
    CLIENT_BASE,
    Msg,
    OP_NOP,
    OP_READ,
    OP_READ_REPLY,
    OP_WRITE,
)


def _cluster(C, n_nodes=4, num_keys=16, protocol="netcraq"):
    return ClusterConfig(
        chain=ChainConfig(n_nodes=n_nodes, num_keys=num_keys,
                          num_versions=4, protocol=protocol),
        n_chains=C,
    )


def _inject_one(sim, op, local_key, val, node, chain, qid):
    """[C, n, c_in] injection with a single live query."""
    m = Msg.empty(sim.c_in)
    m = jax.tree.map(
        lambda x: jnp.tile(x[None, None], (sim.C, sim.n) + (1,) * x.ndim), m
    )
    return m._replace(
        op=m.op.at[chain, node, 0].set(op),
        key=m.key.at[chain, node, 0].set(local_key),
        value=m.value.at[chain, node, 0, 0].set(val),
        src=m.src.at[chain, node, 0].set(CLIENT_BASE + 1),
        client=m.client.at[chain, node, 0].set(CLIENT_BASE + 1),
        dst=m.dst.at[chain, node, 0].set(node),
        qid=m.qid.at[chain, node, 0].set(qid),
    )


def _drain(sim, state, ticks):
    empty = jax.tree.map(
        lambda x: jnp.tile(x[None, None], (sim.C, sim.n) + (1,) * x.ndim),
        Msg.empty(sim.c_in),
    )
    for _ in range(ticks):
        state = sim.tick(state, empty)
    return state


# ---------------------------------------------------------------------------
# partition map
# ---------------------------------------------------------------------------
def test_key_partition_totality():
    """Every global key belongs to exactly one chain; coordinates
    round-trip; the Coordinator serves the same map."""
    cl = _cluster(C=3, num_keys=8)
    co = Coordinator(cl)
    gkeys = np.arange(cl.num_global_keys)
    owners = np.asarray(cl.key_to_chain(gkeys))
    locals_ = np.asarray(cl.local_key(gkeys))
    assert set(owners.tolist()) == {0, 1, 2}
    # each chain owns exactly num_keys global keys
    assert all((owners == c).sum() == cl.chain.num_keys for c in range(3))
    # (chain, local) is a bijection
    coords = set(zip(owners.tolist(), locals_.tolist()))
    assert len(coords) == cl.num_global_keys
    np.testing.assert_array_equal(
        np.asarray(cl.global_key(locals_, owners)), gkeys
    )
    assert [co.key_to_chain(int(g)) for g in gkeys] == owners.tolist()


def test_route_stream_routes_by_partition_map():
    """Stream-routed queries land only in their key's owning chain, with
    the key rewritten to the local register index."""
    cl = _cluster(C=4, num_keys=16)
    T, Q = 3, 24
    rng = np.random.default_rng(0)
    gkeys = jnp.asarray(rng.integers(0, cl.num_global_keys, (T, Q)), jnp.int32)
    ops = jnp.asarray(rng.choice([OP_READ, OP_WRITE, OP_NOP], (T, Q),
                                 p=[0.6, 0.3, 0.1]), jnp.int32)
    base = Msg.empty(Q)
    stream = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (T,) + x.shape), base)
    qid = jnp.arange(T * Q, dtype=jnp.int32).reshape(T, Q)
    stream = stream._replace(op=ops, key=gkeys, qid=qid,
                             src=jnp.full((T, Q), CLIENT_BASE, jnp.int32))
    routed = route_stream(cl, stream, queries_per_node=Q)  # ample headroom
    assert int(routed.dropped) == 0 and int(routed.out_of_range) == 0
    s = jax.tree.map(np.asarray, routed.lanes)
    assert s.op.shape == (T, 4, cl.n_nodes, Q)

    live_in = np.asarray(ops) != OP_NOP
    packed = s.op != OP_NOP
    # conservation: with ample lanes every live query is packed exactly once
    assert packed.sum() == live_in.sum()
    routed_qids = sorted(s.qid[packed].tolist())
    assert routed_qids == sorted(np.asarray(qid)[live_in].tolist())
    # every packed query sits in its key's owning chain with the local key
    gk_by_qid = {int(q): int(k) for q, k in
                 zip(np.asarray(qid).ravel(), np.asarray(gkeys).ravel())}
    chains = np.broadcast_to(np.arange(4)[None, :, None, None], s.op.shape)
    for q, c, lk, op in zip(s.qid[packed], chains[packed], s.key[packed],
                            s.op[packed]):
        g = gk_by_qid[int(q)]
        assert int(c) == int(cl.key_to_chain(g)), (q, c, g)
        assert int(lk) == int(cl.local_key(g))
    # writes are pinned to the owning chain's head
    w = packed & (s.op == OP_WRITE)
    nodes = np.broadcast_to(
        np.arange(cl.n_nodes)[None, None, :, None], s.op.shape)
    assert (nodes[w] == 0).all()


def test_route_stream_counts_dropped_queries():
    """Out-of-range keys and lane overflow are reported, not silently
    dropped (regression: benchmark throughput was overstated by comparing
    replies to an offered load that never got packed)."""
    cl = _cluster(C=2, num_keys=8)  # 16 global keys
    T, Q = 2, 12
    base = Msg.empty(Q)
    stream = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (T,) + x.shape), base)
    keys = jnp.zeros((T, Q), jnp.int32)
    # 3 queries with keys outside the global key space
    keys = keys.at[0, 0].set(99).at[0, 1].set(-1).at[1, 0].set(16)
    stream = stream._replace(
        op=jnp.full((T, Q), OP_READ, jnp.int32),
        key=keys,
        qid=jnp.arange(T * Q, dtype=jnp.int32).reshape(T, Q),
        src=jnp.full((T, Q), CLIENT_BASE, jnp.int32),
    )
    routed = route_stream(cl, stream, queries_per_node=Q)
    assert int(routed.out_of_range) == 3
    assert int(routed.dropped) == 3  # ample lanes: only the bad keys drop
    packed = np.asarray(routed.lanes.op) != OP_NOP
    assert packed.sum() == T * Q - 3

    # starve the lanes: key 0 all lands in one lane of chain 0 -> capacity
    # drops must be counted too
    tight = route_stream(cl, stream, queries_per_node=2)
    live_packed = (np.asarray(tight.lanes.op) != OP_NOP).sum()
    assert int(tight.dropped) == T * Q - live_packed
    assert int(tight.dropped) > int(tight.out_of_range)


# ---------------------------------------------------------------------------
# isolation / linearizability across chains
# ---------------------------------------------------------------------------
def test_write_to_chain_never_visible_via_other_chain():
    """Global keys 6 and 7 share nothing: committing 6 (chain 0) must not
    leak into chain 1's store or replies, even at the same local index."""
    cl = _cluster(C=2, num_keys=8)
    sim = ChainSim(cl, inject_capacity=4, route_capacity=64,
                   reply_capacity=128)
    state = sim.init_state()
    # global key 6 -> chain 0, local 3; global key 7 -> chain 1, local 3
    state = sim.tick(state, _inject_one(sim, OP_WRITE, 3, 999, 0, 0, qid=1))
    state = _drain(sim, state, 8)
    # committed on every node of chain 0, nowhere on chain 1
    assert np.asarray(state.stores.values[0, :, 3, 0, 0]).tolist() == [999] * 4
    assert np.asarray(state.stores.values[1, :, 3, 0, 0]).tolist() == [0] * 4
    assert int(state.stores.pending.sum()) == 0

    # read local 3 via chain 1 (global key 7): must see the initial value
    state = sim.tick(state, _inject_one(sim, OP_READ, 3, 0, 2, 1, qid=2))
    state = _drain(sim, state, 4)
    r = state.replies.merged()
    recs = {int(q): (int(op), int(v))
            for q, op, v in zip(r.qid, r.op, r.value0)}
    assert recs[2] == (OP_READ_REPLY, 0), recs
    # and via chain 0 (global key 6): sees the committed write
    state = sim.tick(state, _inject_one(sim, OP_READ, 3, 0, 2, 0, qid=3))
    state = _drain(sim, state, 4)
    r = state.replies.merged()
    recs = {int(q): (int(op), int(v))
            for q, op, v in zip(r.qid, r.op, r.value0)}
    assert recs[3] == (OP_READ_REPLY, 999), recs


def test_mixed_cluster_workload_chain_isolation():
    """Under a mixed multi-chain workload, every read reply's value was
    written to THAT chain (or is the initial 0) - cross-chain leakage would
    surface as a foreign value."""
    cl = _cluster(C=4, num_keys=4)
    sim = ChainSim(cl, inject_capacity=4, route_capacity=64,
                   reply_capacity=4096)
    wl = WorkloadConfig(ticks=5, queries_per_tick=4, write_fraction=0.4,
                        seed=11)
    sched = make_schedule(cl, wl)
    state = sim.run(sim.init_state(), sched, extra_ticks=16)
    m = state.metrics.asdict()
    assert m["drops"] == 0

    sched_np = jax.tree.map(np.asarray, sched)
    w = sched_np.op == OP_WRITE
    # schedule layout is [T, C, n, q]; collect per-(chain, key) legal values
    legal = {}  # (chain, local_key) -> values written there
    chain_of_qid = {}
    for c in range(4):
        wc = w[:, c]
        for k in np.unique(sched_np.key[:, c][wc]):
            sel = wc & (sched_np.key[:, c] == k)
            legal[(c, int(k))] = set(
                sched_np.value[:, c][sel][:, 0].tolist()) | {0}
        for q in sched_np.qid[:, c][sched_np.qid[:, c] >= 0].ravel():
            chain_of_qid[int(q)] = c
    r = state.replies.merged()
    reads = np.asarray(r.op) == OP_READ_REPLY
    for i in np.where(reads)[0]:
        c = chain_of_qid[int(r.qid[i])]
        v = int(r.value0[i])
        k = int(r.key[i])
        assert v in legal.get((c, k), {0}), (
            f"chain {c} read key {k} returned {v} never written to that chain"
        )


# ---------------------------------------------------------------------------
# C=1 seed equivalence + scaling
# ---------------------------------------------------------------------------
def test_single_chain_cluster_matches_legacy_engine_exactly():
    """ClusterConfig(C=1) must reproduce the legacy single-chain run
    bit-for-bit: same schedule draws, same packets/bytes/replies."""
    cfg = ChainConfig(n_nodes=4, num_keys=32, num_versions=4)
    cl = ClusterConfig(chain=cfg, n_chains=1)
    wl = WorkloadConfig(ticks=4, queries_per_tick=4, write_fraction=0.3,
                        seed=5)
    legacy_sched = make_schedule(cfg, wl)      # [T, n, q]
    cluster_sched = make_schedule(cl, wl)      # [T, 1, n, q]
    for a, b in zip(legacy_sched, cluster_sched):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b[:, 0]))

    sim = ChainSim(cl, inject_capacity=4, route_capacity=64,
                   reply_capacity=1024)
    # legacy-shaped schedule is lifted to the chain axis transparently
    st_legacy = sim.run(sim.init_state(), legacy_sched, extra_ticks=12)
    st_cluster = sim.run(sim.init_state(), cluster_sched, extra_ticks=12)
    assert st_legacy.metrics.asdict() == st_cluster.metrics.asdict()
    # seed-pinned economics: clean reads cost 2 packets, all queries answered
    m = st_cluster.metrics.asdict()
    assert m["replies"] == m["reads_in"] + m["writes_in"]
    assert m["drops"] == 0


def test_aggregate_throughput_scales_with_chains():
    """Fixed per-chain QPS: C=4 must deliver >= 3x the aggregate replies of
    C=1 (acceptance criterion; exact independence gives 4x here), with
    per-reply packet cost unchanged."""
    results = {}
    for C in (1, 4):
        cl = _cluster(C, num_keys=32)
        sim = ChainSim(cl, inject_capacity=8, route_capacity=128,
                       reply_capacity=8192)
        wl = WorkloadConfig(ticks=8, queries_per_tick=8, write_fraction=0.0,
                            entry_node=None, seed=0)
        state = sim.run(sim.init_state(), make_schedule(cl, wl),
                        extra_ticks=16)
        m = state.metrics.asdict()
        results[C] = m
        assert m["drops"] == 0
        # per-chain counters carry the [C] axis and sum to the totals
        pc = state.metrics.per_chain()
        assert len(pc["replies"]) == C
        assert sum(pc["replies"]) == m["replies"]
        assert int(state.metrics.total().replies) == m["replies"]
    assert results[4]["replies"] >= 3 * results[1]["replies"]
    ppr1 = results[1]["packets"] / results[1]["replies"]
    ppr4 = results[4]["packets"] / results[4]["replies"]
    assert ppr1 == ppr4 == 2.0  # clean CRAQ reads, C-independent


# ---------------------------------------------------------------------------
# control plane on a non-zero chain
# ---------------------------------------------------------------------------
def test_fail_and_recover_node_on_nonzero_chain():
    """Surgery on chain 2 of a running [C, n, ...] pytree touches only
    chain 2's slice; other chains keep serving their stores untouched."""
    cl = _cluster(C=3, num_keys=8)
    co = Coordinator(cl)
    sim = ChainSim(cl, inject_capacity=4, route_capacity=64,
                   reply_capacity=512)
    state = sim.init_state()
    # commit distinct values on each chain (same local key 2)
    for c in range(3):
        state = sim.tick(
            state, _inject_one(sim, OP_WRITE, 2, 100 + c, 0, c, qid=10 + c))
    state = _drain(sim, state, 10)
    assert int(state.stores.pending.sum()) == 0

    m = co.fail_node(2, 1)
    assert m.node_ids == [0, 2, 3]
    assert co.chains[0].node_ids == [0, 1, 2, 3]  # other chains untouched

    before = jax.tree.map(np.asarray, state.stores)
    m, copied = co.recover_node(2, new_node_id=1, position=1,
                                stores=state.stores)
    assert m.node_ids == [0, 1, 2, 3]
    # the recovered replica on chain 2 copied its predecessor's committed
    # state (CRAQ rule: position 1 copies from node_ids[0] == 0)
    np.testing.assert_array_equal(
        np.asarray(copied.values[2, 1]), before.values[2, 0])
    assert int(copied.values[2, 1, 2, 0, 0]) == 102
    # chains 0 and 1 are bit-identical to before the surgery
    for c in (0, 1):
        np.testing.assert_array_equal(np.asarray(copied.values[c]),
                                      before.values[c])
        np.testing.assert_array_equal(np.asarray(copied.seqs[c]),
                                      before.seqs[c])
    events = [(e["event"], e["chain"]) for e in co.recovery_log]
    assert events == [("fail", 2), ("recover", 2)]
