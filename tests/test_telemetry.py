"""Telemetry-plane contract tests (core/telemetry.py + obs/hub.py).

What is pinned here, mirroring the telemetry-leaves rules in the
core/chain.py docstring:

* histogram/exact parity: the device histogram sees the SAME exit batch
  the reply log appends, so when the log doesn't overflow the hub's
  nearest-rank percentile lands in exactly the bucket of the exact
  ReplyLog percentile (the shared ``latency_bucket`` makes the check
  structural, not numerical);
* the flight-recorder ring wraps: ``ring_cursor`` counts all rows ever
  written and the unwrapped window is the last W consecutive ticks;
* sampled traces are deterministic: a pure function of the schedule
  (two fresh engines agree bit-for-bit), every claimed slot's qid
  satisfies the sampling predicate, and hop ticks strictly increase
  (at most one event per slot per tick);
* ``telemetry=False`` compiles the plane out bit-identically (the
  ``wave_depth == 0`` pattern): data-path results equal the
  telemetry-on run and every telemetry leaf is zero-size;
* ``Metrics.heat_ewma`` has the advertised fixpoint under constant
  interval heat, and the hub's snapshot/rates/JSONL pipeline round-trips.
"""
from __future__ import annotations

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (ChainConfig, ChainSim, ClusterConfig, WorkloadConfig,
                        make_schedule)
from repro.core.metrics import Metrics
from repro.core.telemetry import (TRACE_SAMPLE_BITS, latency_bucket,
                                  trace_hash, trace_sampled)
from repro.core.types import OPCLASS_NAMES
from repro.obs import TelemetryHub

C, N, Q, TICKS, EXTRA = 2, 4, 4, 6, 16


def _engine(telemetry: bool = True, **kw) -> ChainSim:
    cluster = ClusterConfig(
        chain=ChainConfig(n_nodes=N, num_keys=16, num_versions=6),
        n_chains=C)
    return ChainSim(cluster, inject_capacity=Q, route_capacity=64,
                    reply_capacity=2048, telemetry=telemetry, **kw)


def _run(sim: ChainSim, seed: int = 11, wf: float = 0.3):
    wl = WorkloadConfig(ticks=TICKS, queries_per_tick=Q, write_fraction=wf,
                        entry_node=None, seed=seed)
    return sim.run(sim.init_state(), make_schedule(sim.cluster, wl),
                   extra_ticks=EXTRA)


def test_histogram_matches_exact_reply_log():
    state = _run(_engine())
    hub = TelemetryHub()
    hub.snapshot(state)
    pct = hub.percentiles(qs=(50.0, 90.0, 99.0))
    exact = TelemetryHub.exact_percentiles(state.replies, qs=(50.0, 90.0, 99.0))
    # every logged reply classified: histogram mass == log cursor total
    hist_total = int(np.asarray(state.telemetry.lat_hist).sum())
    assert hist_total == int(np.asarray(state.replies.cursor).sum())
    assert hist_total > 0
    seen = 0
    for cname in OPCLASS_NAMES:
        if pct[cname] is None:
            assert exact[cname] is None
            continue
        seen += 1
        for qn, rec in pct[cname].items():
            # ample reply capacity -> same multiset -> same bucket exactly
            assert rec["bucket"] == exact[cname][qn]["bucket"], (cname, qn)
            assert rec["ticks"] == 1 << rec["bucket"]
    assert seen >= 2  # the mixed workload exercises reads AND writes


def test_latency_bucket_shared_math():
    # host (numpy) and device (jnp) inputs agree; bucket b = floor(log2)
    for ticks, want in ((0, 0), (1, 0), (2, 1), (3, 1), (4, 2), (7, 2),
                        (8, 3), (1 << 14, 14), (1 << 15, 15), (1 << 20, 15)):
        assert int(latency_bucket(np.asarray(ticks), 16)) == want
        assert int(latency_bucket(jnp.asarray(ticks), 16)) == want
    batch = np.asarray([1, 5, 9, 300])
    np.testing.assert_array_equal(np.asarray(latency_bucket(batch, 16)),
                                  [0, 2, 3, 8])


def test_ring_wraps_and_unwraps_to_last_window():
    sim = _engine(ring_window=4)
    state = _run(sim)
    total_ticks = int(state.t)
    assert total_ticks == TICKS + EXTRA
    cur = np.asarray(state.telemetry.ring_cursor)
    np.testing.assert_array_equal(cur, total_ticks)  # one row per tick
    hub = TelemetryHub()
    hub.snapshot(state)
    for window in hub.ring_window():
        assert window.shape == (4, len(window[0]))
        # rows unwrap oldest -> newest: the last 4 consecutive tick stamps
        np.testing.assert_array_equal(
            window[:, 0], np.arange(total_ticks - 4, total_ticks))


def test_trace_sampling_is_deterministic_and_hash_consistent():
    s1 = _run(_engine())
    s2 = _run(_engine())
    for a, b in zip(s1.telemetry, s2.telemetry):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    tel = s1.telemetry
    qids = np.asarray(tel.trace_qid)
    lens = np.asarray(tel.trace_len)
    ticks = np.asarray(tel.trace_tick)
    nodes = np.asarray(tel.trace_node)
    claimed = qids >= 0
    assert claimed.any(), "the seeded schedule samples at least one qid"
    mask = (1 << TRACE_SAMPLE_BITS) - 1
    for c, s in zip(*np.nonzero(claimed)):
        q = int(qids[c, s])
        assert int(np.asarray(trace_hash(q))) & mask == 0
        assert bool(np.asarray(trace_sampled(q)))
        h = int(lens[c, s])
        assert h >= 1
        # one event per tick, in tick order, at live nodes
        assert np.all(np.diff(ticks[c, s, :h]) >= 1)
        assert np.all((nodes[c, s, :h] >= 0) & (nodes[c, s, :h] < N))


def test_telemetry_off_is_bit_identical_and_zero_size():
    on = _run(_engine(True))
    off = _run(_engine(False))
    for a, b in zip(on.replies, off.replies):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(on.metrics, off.metrics):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(on.stores.values),
                                  np.asarray(off.stores.values))
    assert int(on.t) == int(off.t)
    # the off engine's telemetry leaves ride the pytree at zero size
    assert all(np.asarray(leaf).size == 0 or leaf.ndim == 1  # ring_cursor [C]
               for leaf in off.telemetry)
    assert np.asarray(off.telemetry.lat_hist).size == 0
    assert np.asarray(off.telemetry.ring).size == 0
    assert np.asarray(off.telemetry.trace_qid).size == 0


def test_heat_ewma_fixpoint_under_constant_load():
    heat = jnp.asarray([[2, 4, 6], [1, 0, 3]], jnp.int32)  # [C, B]
    interval = Metrics.zeros(num_buckets=3)._replace(conflict_heat=heat)
    total = interval.heat_per_bucket()
    assert total == [3, 4, 9]
    # prev == the constant interval heat maps to itself exactly (alpha=0.5
    # keeps the arithmetic exact in binary floating point)
    fix = [float(h) for h in total]
    assert interval.heat_ewma(fix, alpha=0.5) == fix
    # and the iteration converges to that fixpoint from cold
    cur = None
    for _ in range(60):
        cur = interval.heat_ewma(cur, alpha=0.3)
    assert cur == pytest.approx(fix, abs=1e-6)
    # prev=None starts from zeros
    assert interval.heat_ewma(None, alpha=0.5) == [h / 2 for h in fix]


def test_hub_rates_jsonl_and_summary(tmp_path):
    sim = _engine()
    hub = TelemetryHub(us_per_tick=2.5)
    state = _run(sim)
    hub.snapshot(state)
    state = sim.drain(state, 4)
    hub.snapshot(state)

    rates = hub.rates()
    assert rates is not None and rates["replies"] >= 0.0
    assert set(rates) == {"replies", "packets", "drops", "lock_conflicts",
                          "stale_routes", "write_nacks", "lease_expiries"}
    path = tmp_path / "telemetry.jsonl"
    hub.write_jsonl(str(path))
    lines = path.read_text().splitlines()
    assert len(lines) == 2
    recs = [json.loads(ln) for ln in lines]
    assert all(r["kind"] == "telemetry_snapshot" for r in recs)
    assert recs[0]["rates"] is None and recs[1]["rates"] is not None
    assert recs[1]["percentiles"]["read"]["p50"]["us"] > 0
    assert recs[1]["ring"]["fields"][0] == "tick"
    text = hub.summary()
    assert "read" in text and "p999" in text and "rates/tick" in text


def test_snapshot_reads_returned_state_not_donated_input():
    """The hub observes the *returned* state of a tick (the donation
    contract): snapshotting then ticking again must work, and the
    histogram only ever grows between snapshots."""
    sim = _engine()
    hub = TelemetryHub()
    state = sim.init_state()
    wl = WorkloadConfig(ticks=TICKS, queries_per_tick=Q, write_fraction=0.3,
                        entry_node=None, seed=11)
    sched = make_schedule(sim.cluster, wl)
    prev_total = 0
    for t in range(TICKS):
        state = sim.tick(state, jax.tree.map(lambda x: x[t], sched))
        snap = hub.snapshot(state)
        total = int(snap.lat_hist.sum())
        assert total >= prev_total
        prev_total = total
    state = sim.drain(state, EXTRA)
    snap = hub.snapshot(state)
    assert int(snap.lat_hist.sum()) >= prev_total
