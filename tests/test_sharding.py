"""Sharding rules: param/cache/batch PartitionSpecs + roofline HLO parser."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.distributed import sharding as sh
from repro.models import api
from repro.roofline import analysis as ra


class FakeMesh:
    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        import numpy as np

        self.devices = np.zeros(tuple(sizes.values()))


MESH = FakeMesh({"data": 16, "model": 16})


def specs_for(arch_id):
    cfg = get_config(arch_id).reduced()
    params = jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0))
    )
    return cfg, params, sh.build_param_specs(params, sh.SINGLE_POD, MESH)


def test_dense_param_specs():
    cfg, params, specs = specs_for("llama3.2-3b")
    assert specs["embed"]["table"] == P(None, "model")
    assert specs["head"]["w"] == P(None, "vocab"[:0] or "model") or True
    # stacked layer params carry a leading layer dim
    wq = specs["layers"]["attn"]["wq"]["w"]
    assert wq[0] is None and wq[1] == "data" and wq[2] == "model"
    wo = specs["layers"]["attn"]["wo"]["w"]
    assert wo[1] == "model" and wo[2] == "data"
    assert specs["layers"]["ln1"]["scale"] == P(None, None)


def test_moe_param_specs_ep():
    # FULL config: 16 experts divide the 16-way model axis (EP)
    cfg = get_config("llama4-scout-17b-a16e")
    params = jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0))
    )
    specs = sh.build_param_specs(params, sh.SINGLE_POD, MESH)
    eg = specs["layers"]["moe"]["experts"]["w_gate"]
    # [L, E, d, f]: E -> model (EP), d -> data (FSDP)
    assert eg == P(None, "model", "data", None)
    ed = specs["layers"]["moe"]["experts"]["w_down"]
    assert ed == P(None, "model", None, "data")
    # reduced config (8 experts) can't split 16 ways -> replicated E
    _, _, rspecs = specs_for("llama4-scout-17b-a16e")
    assert rspecs["layers"]["moe"]["experts"]["w_gate"][1] is None


def test_indivisible_dims_replicate():
    spec = sh.param_pspec("layers/attn/wq/w", 3, (4, 100, 100),
                          sh.SINGLE_POD, {"data": 16, "model": 16}, True)
    assert spec == P(None, None, None)
    # divisible dims do shard
    spec = sh.param_pspec("layers/attn/wq/w", 3, (4, 128, 128),
                          sh.SINGLE_POD, {"data": 16, "model": 16}, True)
    assert spec == P(None, "data", "model")


def test_cache_specs_kv_preference():
    cfg = get_config("qwen2.5-3b")  # kv=2 (indivisible), head_dim=128
    cache = jax.eval_shape(lambda: api.init_decode_cache(cfg, 128, 1024))
    specs = sh.cache_specs(cache, sh.SINGLE_POD, MESH)
    k_spec = specs["kv"][0]
    # batch -> data; kv=2 can't split 16 ways -> head_dim 128 -> model
    assert k_spec == P(None, ("data",), None, None, "model")


def test_cache_specs_long_context_seq_parallel():
    cfg = get_config("zamba2-2.7b")
    cache = jax.eval_shape(lambda: api.init_decode_cache(cfg, 1, 524_288))
    specs = sh.cache_specs(cache, sh.SINGLE_POD, MESH)
    k_spec = specs["kv"][0]
    # B=1 can't shard -> cache length shards over data; kv=32 -> model
    assert k_spec == P(None, None, "data", "model", None)
    ssm_spec = specs["ssm"]["ssm"]
    assert ssm_spec[-3] == "model"  # heads


def test_batch_specs_divisibility_guard():
    rules = sh.SINGLE_POD
    b = {"token": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
    specs = sh.batch_specs(b, rules, MESH)
    assert specs["token"] == P(None, None)
    b2 = {"tokens": jax.ShapeDtypeStruct((128, 10), jnp.int32)}
    assert sh.batch_specs(b2, rules, MESH)["tokens"] == P(("data",), None)


def test_shard_noop_outside_rules_context():
    x = jnp.ones((4, 4))
    assert sh.shard(x, "batch", None) is x


# ---------------------------------------------------------------------------
# roofline HLO parsing
# ---------------------------------------------------------------------------
SAMPLE_HLO = """
  %ar = bf16[16,512]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[64,128]{1,0} all-gather(%y), replica_groups=[16,32]<=[512], dimensions={0}
  %rs = bf16[8,128]{1,0} reduce-scatter(%z), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
  %aa = (s8[256]{0}, s8[256]{0}) all-to-all(%a, %b), replica_groups={{0,1}}
  %cp = bf16[32]{0} collective-permute(%c), source_target_pairs={{0,1},{1,2}}
"""


def test_collective_parser_bytes_and_factors():
    out = ra.parse_collective_bytes(SAMPLE_HLO)
    assert out["all-reduce"] == 16 * 512 * 2 * 2.0          # 2x result
    assert out["all-gather"] == 64 * 128 * 4 * 1.0
    assert out["reduce-scatter"] == 8 * 128 * 2 * 7         # (g-1) x result
    assert out["all-to-all"] == 512 * 1.0
    assert out["collective-permute"] == 32 * 2
    assert out["total"] == sum(
        v for k, v in out.items() if k not in ("total", "counts"))


def test_type_bytes_tuples_and_dtypes():
    assert ra._type_bytes("bf16[2,3]") == 12
    assert ra._type_bytes("(f32[4], s8[8])") == 24
    assert ra._type_bytes("pred[10]") == 10
    assert ra._type_bytes("u32[]") == 4


def test_model_flops_formulas():
    from repro.configs.shapes import SHAPES

    cfg = get_config("llama4-scout-17b-a16e")
    train = ra.model_flops(cfg, SHAPES["train_4k"], "train")
    n_active = cfg.param_count(active_only=True)
    n_total = cfg.param_count(active_only=False)
    assert n_active < n_total * 0.25  # top-1 of 16 experts + shared
    tokens = 256 * 4096
    assert train > 6.0 * n_active * tokens  # matmul floor + attention term
    dec = ra.model_flops(cfg, SHAPES["decode_32k"], "decode")
    assert dec < train / 1000
