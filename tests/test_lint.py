"""repro-lint contract tests.

Four layers:

1. corpus - every rule fires on its known-bad exemplar and stays silent
   on the clean twin (the linter detects what it claims and nothing
   else);
2. pragmas - suppression works in both placement forms, ``--strict``
   rejects reason-less pragmas, unknown rule ids are findings, and the
   *total* pragma count across the walked tree is pinned so
   suppressions cannot silently accumulate;
3. acceptance - the shipping tree lints clean under ``--strict``, and
   the guarantee is load-bearing: deleting any one pragma, or reverting
   the RL003 dtype-pin fix in ``core/chain.py``, flips the exit to
   non-zero;
4. reporters - the JSON report round-trips Finding-for-Finding and the
   CLI exit codes hold (0 clean / 1 findings / 2 usage).

Pure-ast: none of this imports jax, so the lint lane stays fast.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

import pytest

from repro.analysis import RULES, run_lint, run_lint_sources, walk_paths
from repro.analysis.pragmas import scan_pragmas
from repro.analysis.report import findings_from_json, render_json

REPO = pathlib.Path(__file__).resolve().parent.parent
CORPUS = REPO / "tests" / "lint_corpus"
LINT_PATHS = ["src", "benchmarks", "tests", "examples"]

# The audited suppression budget for the whole walked tree.  If you add
# a pragma, justify it in review and bump this - that friction is the
# point (suppressions must not accumulate silently).
EXPECTED_TREE_PRAGMAS = 1

ALL_RULES = ("RL001", "RL002", "RL003", "RL004", "RL005")


def _lint_corpus_file(name: str, **kw):
    return run_lint([str(CORPUS / name)], **kw)


def _cli(*args: str, cwd=REPO):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd, env=env, capture_output=True, text=True,
    )


# --------------------------------------------------------------------------
# 1. corpus: each rule fires on bad, stays silent on clean
# --------------------------------------------------------------------------

@pytest.mark.parametrize("rule_id", ALL_RULES)
def test_rule_fires_on_bad_exemplar(rule_id):
    result = _lint_corpus_file(f"{rule_id.lower()}_bad.py")
    per_rule = result.per_rule()
    assert per_rule.get(rule_id, 0) > 0, (
        f"{rule_id} did not fire on its bad exemplar: {result.findings}"
    )
    # the exemplar is single-purpose: no other rule may fire on it
    assert set(per_rule) == {rule_id}, per_rule


@pytest.mark.parametrize("rule_id", ALL_RULES)
def test_rule_silent_on_clean_twin(rule_id):
    result = _lint_corpus_file(f"{rule_id.lower()}_clean.py", strict=True)
    assert result.findings == [], result.findings


def test_rule_catalogue_registered():
    assert set(RULES) == set(ALL_RULES)
    for rule in RULES.values():
        assert rule.summary and rule.rationale


def test_expected_finding_counts():
    """Pin the exemplar finding counts so rule regressions are loud."""
    expected = {"RL001": 2, "RL002": 2, "RL003": 4, "RL004": 6, "RL005": 2}
    for rule_id, n in expected.items():
        result = _lint_corpus_file(f"{rule_id.lower()}_bad.py")
        assert result.per_rule()[rule_id] == n, (rule_id, result.findings)


def test_telemetry_exemplars_pin_the_telemetry_leaves_rules():
    """The telemetry-plane contract in core/chain.py points here: the bad
    twin breaks the traced-leaf rules in exactly the two machine-checked
    ways (RL002 closure-captured histogram/ring, RL003 weak literals into
    the int32 telemetry lanes) and nothing else fires on it; the clean
    twin - written the way the engine actually carries its plane - is
    strict-silent."""
    bad = _lint_corpus_file("telemetry_bad.py")
    per_rule = bad.per_rule()
    assert per_rule == {"RL002": 2, "RL003": 3}, bad.findings
    clean = _lint_corpus_file("telemetry_clean.py", strict=True)
    assert clean.findings == [], clean.findings


def test_loadgen_exemplars_pin_the_openloop_harness_rules():
    """The open-loop harness contract in core/chain.py points here: the
    bad twin bakes the workload into the executable in exactly the two
    machine-checked ways (RL002 module-level rate schedule /
    closure-captured popularity CDF inside jitted drawers, RL003 weak
    literals into the generator's float32/int32 sweep lanes) and nothing
    else fires on it; the clean twin - written the way core/loadgen.py
    actually threads its knobs - is strict-silent."""
    bad = _lint_corpus_file("loadgen_bad.py")
    per_rule = bad.per_rule()
    assert per_rule == {"RL002": 2, "RL003": 3}, bad.findings
    clean = _lint_corpus_file("loadgen_clean.py", strict=True)
    assert clean.findings == [], clean.findings


def test_lease_exemplars_pin_the_lock_lease_rules():
    """The lock-lease contract in core/chain.py points here: the bad twin
    breaks the traced-leaf rules in exactly the two machine-checked ways
    (RL002 module-level lease stamps / closure-captured stamps inside
    jitted expiry stages, RL003 weak literals into the int32 lease lanes)
    and nothing else fires on it; the clean twin - written the way
    core/txn.py actually threads its lease clock - is strict-silent."""
    bad = _lint_corpus_file("lease_bad.py")
    per_rule = bad.per_rule()
    assert per_rule == {"RL002": 2, "RL003": 3}, bad.findings
    clean = _lint_corpus_file("lease_clean.py", strict=True)
    assert clean.findings == [], clean.findings


# --------------------------------------------------------------------------
# 2. pragmas
# --------------------------------------------------------------------------

def test_pragma_suppresses_both_placement_forms():
    result = _lint_corpus_file("pragma_ok.py", strict=True)
    assert result.findings == []
    assert len(result.suppressed) == 2
    assert all(f.rule == "RL005" for f in result.suppressed)
    assert all(p.reason for p in result.pragmas)


def test_pragma_without_reason_rejected_by_strict():
    lax = _lint_corpus_file("pragma_noreason.py")
    assert lax.findings == [] and len(lax.suppressed) == 1
    strict = _lint_corpus_file("pragma_noreason.py", strict=True)
    assert any(
        f.rule == "RL000" and "no reason" in f.message
        for f in strict.findings
    ), strict.findings


def test_unknown_rule_id_in_pragma_is_a_finding():
    src = (
        "def f(inbox, dst, m):\n"
        '    """repro-lint: scatter-free"""\n'
        "    return inbox.at[dst].set(m)  "
        "# repro-lint: ignore[RL999] typo'd id\n"
    )
    result = run_lint_sources({"x.py": src})
    rules = {f.rule for f in result.findings}
    # the typo'd pragma doesn't suppress RL005 AND is itself flagged
    assert rules == {"RL000", "RL005"}, result.findings


def test_pragma_strings_do_not_count():
    """Only real comments are pragmas (tokenize, not regex-over-lines)."""
    src = 's = "# repro-lint: ignore[RL005] not a comment"\n'
    result = run_lint_sources({"x.py": src})
    assert result.pragmas == [] and result.findings == []


def test_tree_pragma_budget():
    files = walk_paths([str(REPO / p) for p in LINT_PATHS])
    pragmas = []
    for f in files:
        pragmas.extend(scan_pragmas(str(f), f.read_text()))
    assert len(pragmas) == EXPECTED_TREE_PRAGMAS, [
        f"{p.path}:{p.line}" for p in pragmas
    ]
    assert all(p.reason for p in pragmas), "tree pragmas must carry reasons"


# --------------------------------------------------------------------------
# 3. acceptance: the tree is clean, and the guarantee is load-bearing
# --------------------------------------------------------------------------

def _tree_sources() -> dict[str, str]:
    return {
        str(f): f.read_text()
        for f in walk_paths([str(REPO / p) for p in LINT_PATHS])
    }


def test_tree_lints_clean_under_strict():
    proc = _cli(*LINT_PATHS, "--strict")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_deleting_any_pragma_breaks_strict():
    sources = _tree_sources()
    pragma_sites = [
        (path, p)
        for path, src in sources.items()
        for p in scan_pragmas(path, src)
    ]
    assert len(pragma_sites) == EXPECTED_TREE_PRAGMAS
    for path, pragma in pragma_sites:
        lines = sources[path].splitlines(keepends=True)
        i = pragma.line - 1
        if pragma.own_line:
            del lines[i]
        else:
            lines[i] = lines[i].split("#")[0].rstrip() + "\n"
        mutated = dict(sources)
        mutated[path] = "".join(lines)
        result = run_lint_sources(mutated, strict=True)
        assert result.findings, (
            f"deleting pragma at {path}:{pragma.line} did not re-expose "
            "its finding"
        )


def test_reverting_rl003_fix_breaks_lint():
    """The dtype-pin fix in core/chain.py is load-bearing: restoring the
    weak `jnp.where(is_exit, 1, 0)` hop term re-fires RL003."""
    sources = _tree_sources()
    chain = str(REPO / "src" / "repro" / "core" / "chain.py")
    fixed = "+ is_exit.astype(jnp.int32)"
    assert fixed in sources[chain], "expected the pinned hop term"
    mutated = dict(sources)
    mutated[chain] = sources[chain].replace(
        fixed, "+ jnp.where(is_exit, 1, 0)"
    )
    clean = run_lint_sources(sources, strict=True)
    assert clean.findings == []
    broken = run_lint_sources(mutated, strict=True)
    assert any(
        f.rule == "RL003" and f.path == chain for f in broken.findings
    ), broken.findings


def test_scatter_free_tags_cover_the_fabric():
    chain_src = (REPO / "src" / "repro" / "core" / "chain.py").read_text()
    for fn in ("segmented_route", "cluster_route"):
        body = chain_src.split(f"def {fn}(")[1]
        docstring = body.split('"""')[1]
        assert "repro-lint: scatter-free" in docstring, (
            f"{fn} lost its scatter-free contract tag"
        )


# --------------------------------------------------------------------------
# 4. reporters and CLI
# --------------------------------------------------------------------------

def test_json_report_round_trips(tmp_path):
    out = tmp_path / "report.json"
    proc = _cli(str(CORPUS / "rl005_bad.py"), "--json", str(out))
    assert proc.returncode == 1
    report = json.loads(out.read_text())
    assert report["version"] == 1
    decoded = findings_from_json(report)
    api = run_lint([str(CORPUS / "rl005_bad.py")])
    assert decoded == api.findings
    assert report["summary"] == {"total": 2, "per_rule": {"RL005": 2}}
    # and the dict form itself round-trips through the renderer
    assert render_json(api)["findings"] == report["findings"]


def test_human_output_format():
    proc = _cli(str(CORPUS / "rl005_bad.py"))
    first = proc.stdout.splitlines()[0]
    path, line, col, rest = first.split(":", 3)
    assert path.endswith("rl005_bad.py") and line.isdigit() and col.isdigit()
    assert rest.strip().startswith("RL005")


def test_cli_exit_codes(tmp_path):
    assert _cli().returncode == 2                       # no paths
    assert _cli("no/such/path").returncode == 2         # missing path
    assert _cli("--rules", "RL9", ".").returncode == 2  # unknown rule
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    assert _cli(str(clean)).returncode == 0
    assert _cli(str(CORPUS / "rl001_bad.py")).returncode == 1


def test_rule_subset_selection():
    result = run_lint(
        [str(CORPUS / "rl004_bad.py")], rules=["RL001", "RL002"]
    )
    assert result.findings == []  # RL004 not selected -> nothing fires


def test_list_rules():
    proc = _cli("--list-rules")
    assert proc.returncode == 0
    for rid in ALL_RULES:
        assert rid in proc.stdout


def test_corpus_excluded_from_directory_walks():
    files = walk_paths([str(REPO / "tests")])
    assert not any("lint_corpus" in str(f) for f in files)
    # but explicit file paths are always linted
    explicit = walk_paths([str(CORPUS / "rl001_bad.py")])
    assert len(explicit) == 1


def test_syntax_error_is_a_meta_finding():
    result = run_lint_sources({"broken.py": "def f(:\n"})
    assert result.findings and result.findings[0].rule == "RL000"
