"""Segmented-sort routing fabric == the old per-node-argsort router.

The tick's fabric was rewritten from a dense [n, M] delivery matrix plus a
per-node ``argsort(~mask, stable=True)`` compaction (O(n * M log M)) to one
segmented stable sort keyed by (destination, original index) (O(M log M) -
see ``segmented_route`` in core/chain.py).  These tests pin the rewrite to
a straight-line numpy re-statement of the old router's delivery contract
(tests/helpers.py ``reference_route_numpy``): bit-identical [n, c_route]
inboxes (every field, including the per-copy multicast hop accumulation in
``extra``), per-node drop counts, and multicast copy/hop totals - under
random masked outboxes, over-capacity destinations, all-NOP batches,
multicast-heavy storms, dead nodes and adversarial src fields.

The hypothesis twin lives in tests/test_fabric_properties.py (same checker,
minimized example source); whole-engine equivalence (a full ChainSim run on
each fabric) is pinned at the bottom.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ChainConfig, ChainSim, ClusterConfig, WorkloadConfig
from repro.core.workload import make_schedule
from tests.helpers import check_fabric_equivalence, random_outbox_fields

N, WIDTH, C_ROUTE = 4, 9, 5  # tiny capacity -> over-capacity drops abound


def _alive_and_pos(rng, n):
    """Random health vector + the live-chain coordinates the role table
    would derive from it (dead slots carry NOWHERE = -1)."""
    alive = rng.random(n) > 0.25
    if alive.sum() < 2:
        alive[:2] = True
    pos = np.full(n, -1, np.int32)
    pos[np.flatnonzero(alive)] = np.arange(int(alive.sum()))
    return alive, pos


@pytest.mark.parametrize("seed", range(8))
def test_random_outboxes_bit_identical(seed):
    rng = np.random.default_rng(seed)
    for _ in range(6):
        fields = random_outbox_fields(rng, N, WIDTH)
        alive, pos = _alive_and_pos(rng, N)
        # the engine's exact lane bound: src == emitting node, so one
        # source contributes at most its own outbox width
        check_fabric_equivalence(
            fields, alive, pos, C_ROUTE,
            mcast_lane=C_ROUTE + (N * WIDTH) // N,
        )


def test_multicast_heavy_storm():
    """Fan-out-dominated traffic: most live slots are MULTICAST, so every
    node's inbox is mostly copies and the per-copy hop accounting and the
    bounded multicast lane both get stressed."""
    rng = np.random.default_rng(7)
    for _ in range(6):
        fields = random_outbox_fields(rng, N, WIDTH, mcast_heavy=True)
        alive, pos = _alive_and_pos(rng, N)
        check_fabric_equivalence(
            fields, alive, pos, C_ROUTE,
            mcast_lane=C_ROUTE + (N * WIDTH) // N,
        )


def test_adversarial_src_full_lane():
    """src fields the engine can never produce (out of range, not the
    emitting node): the lane bound no longer applies, so route with
    mcast_lane=M and demand exactness anyway."""
    rng = np.random.default_rng(11)
    for _ in range(6):
        fields = random_outbox_fields(
            rng, N, WIDTH, adversarial_src=True, mcast_heavy=True
        )
        alive, pos = _alive_and_pos(rng, N)
        check_fabric_equivalence(fields, alive, pos, C_ROUTE, mcast_lane=None)


def test_all_nop_outbox():
    fields = random_outbox_fields(np.random.default_rng(0), N, WIDTH)
    for k in fields:
        fields[k] = np.zeros_like(fields[k])
    fields["seq"] -= 1
    fields["qid"] -= 1
    fields["dst"] -= 1  # NOWHERE
    alive = np.ones(N, bool)
    check_fabric_equivalence(fields, alive, np.arange(N), C_ROUTE)


def test_over_capacity_single_destination():
    """Every live slot unicast to node 0: the first c_route (in flat-outbox
    order) land, the rest are counted dropped."""
    rng = np.random.default_rng(3)
    fields = random_outbox_fields(rng, N, WIDTH)
    live = fields["op"] != 0
    fields["dst"][live] = 0
    check_fabric_equivalence(
        fields, np.ones(N, bool), np.arange(N), C_ROUTE
    )


def test_degenerate_shapes():
    """Two-node chains, single-slot outboxes, inbox as wide as the whole
    outbox - the clamp/sentinel arithmetic must hold at the edges, not
    just at scale.  (c_route <= M is the fabric contract: the engine's
    outbox is always several times wider than the inbox it feeds.)"""
    rng = np.random.default_rng(2)
    for n, width, c_route in ((2, 1, 2), (2, 2, 4), (3, 1, 2)):
        for _ in range(4):
            fields = random_outbox_fields(rng, n, width, mcast_heavy=True)
            alive, pos = _alive_and_pos(rng, n)
            check_fabric_equivalence(
                fields, alive, pos, c_route, mcast_lane=c_route + width
            )


def test_whole_engine_run_bit_identical_across_fabrics():
    """End to end: a mixed read/write cluster workload produces the exact
    same SimState (stores, inboxes, metrics, reply logs) on the segmented
    fabric as on the dense reference."""
    cluster = ClusterConfig(
        chain=ChainConfig(n_nodes=4, num_keys=32, num_versions=6),
        n_chains=2,
    )
    wl = WorkloadConfig(ticks=6, queries_per_tick=6, write_fraction=0.4,
                        entry_node=None, seed=5)
    sched = make_schedule(cluster, wl)
    finals = {}
    for fabric in ("dense", "segmented"):
        sim = ChainSim(cluster, inject_capacity=6, route_capacity=24,
                       reply_capacity=512, fabric=fabric)
        finals[fabric] = sim.run(sim.init_state(), sched, extra_ticks=16)
    a, b = finals["dense"], finals["segmented"]
    for leaf_a, leaf_b in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(
            np.asarray(leaf_a), np.asarray(leaf_b)
        )
