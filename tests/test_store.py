"""Unit tests for the versioned object store (core/store.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import store as st
from repro.core.types import ChainConfig


@pytest.fixture
def cfg():
    return ChainConfig(n_nodes=4, num_keys=16, num_versions=4)


def test_init_clean(cfg):
    s = st.init_store(cfg)
    assert bool(st.is_clean(s, jnp.arange(16)).all())
    v, q = st.read_clean(s, jnp.asarray([3]))
    assert v.shape == (1, cfg.value_words)
    assert int(q[0]) == 0


def test_append_and_read_latest(cfg):
    s = st.init_store(cfg)
    keys = jnp.asarray([5, 5, 7], jnp.int32)
    vals = jnp.asarray([[1, 0, 0, 0], [2, 0, 0, 0], [3, 0, 0, 0]], jnp.int32)
    seqs = jnp.asarray([1, 2, 1], jnp.int32)
    active = jnp.asarray([True, True, True])
    s, acc = st.append_dirty(s, keys, vals, seqs, active)
    assert acc.tolist() == [True, True, True]
    assert int(s.pending[5]) == 2 and int(s.pending[7]) == 1
    lv, ls = st.read_latest(s, jnp.asarray([5, 7]))
    assert lv[:, 0].tolist() == [2, 3]
    assert ls.tolist() == [2, 1]
    # clean read still returns the committed (initial) version
    cv, cs = st.read_clean(s, jnp.asarray([5]))
    assert int(cv[0, 0]) == 0 and int(cs[0]) == 0


def test_window_overflow_drops(cfg):
    """Writes beyond the version window are dropped (Algorithm 1 l.22-23)."""
    s = st.init_store(cfg)
    n = cfg.num_versions  # window has n-1 dirty slots
    keys = jnp.full((n + 2,), 3, jnp.int32)
    vals = jnp.tile(jnp.arange(n + 2, dtype=jnp.int32)[:, None], (1, 4))
    seqs = jnp.arange(1, n + 3, dtype=jnp.int32)
    s, acc = st.append_dirty(s, keys, vals, seqs, jnp.ones(n + 2, bool))
    assert acc.tolist() == [True] * (n - 1) + [False] * 3
    assert int(s.pending[3]) == n - 1


def test_commit_compacts(cfg):
    s = st.init_store(cfg)
    keys = jnp.asarray([5, 5, 5], jnp.int32)
    vals = jnp.asarray([[10, 0, 0, 0], [20, 0, 0, 0], [30, 0, 0, 0]], jnp.int32)
    seqs = jnp.asarray([1, 2, 3], jnp.int32)
    s, _ = st.append_dirty(s, keys, vals, seqs, jnp.ones(3, bool))
    # ack seq 2: versions 1,2 deleted; version 3 shifts down; cell0 = 20
    s = st.commit(
        s, jnp.asarray([5]), jnp.asarray([[20, 0, 0, 0]]), jnp.asarray([2]),
        jnp.asarray([True]),
    )
    assert int(s.pending[5]) == 1
    assert int(s.values[5, 0, 0]) == 20 and int(s.seqs[5, 0]) == 2
    lv, ls = st.read_latest(s, jnp.asarray([5]))
    assert int(lv[0, 0]) == 30 and int(ls[0]) == 3


def test_commit_stale_ack_noop(cfg):
    s = st.init_store(cfg)
    s = st.commit(
        s, jnp.asarray([2]), jnp.asarray([[9, 0, 0, 0]]), jnp.asarray([5]),
        jnp.asarray([True]),
    )
    # older ack must not roll back
    s2 = st.commit(
        s, jnp.asarray([2]), jnp.asarray([[7, 0, 0, 0]]), jnp.asarray([3]),
        jnp.asarray([True]),
    )
    assert int(s2.values[2, 0, 0]) == 9 and int(s2.seqs[2, 0]) == 5


def test_batch_rank_serialization():
    keys = jnp.asarray([1, 2, 1, 1, 2], jnp.int32)
    active = jnp.asarray([True, True, True, False, True])
    rank = st.batch_rank(keys, active)
    assert rank.tolist() == [0, 0, 1, 0, 1]  # inactive rows don't count


def test_assign_seqs_monotone(cfg):
    s = st.init_store(cfg)
    keys = jnp.asarray([4, 4, 9], jnp.int32)
    s, seqs = st.assign_seqs(s, keys, jnp.ones(3, bool))
    assert seqs.tolist() == [1, 2, 1]
    s, seqs2 = st.assign_seqs(s, keys, jnp.ones(3, bool))
    assert seqs2.tolist() == [3, 4, 2]


def test_overwrite_clean_netchain(cfg):
    """CR single-version write: newest seq wins, stale writes ignored."""
    s = st.init_store(cfg)
    keys = jnp.asarray([1, 1], jnp.int32)
    vals = jnp.asarray([[5, 0, 0, 0], [6, 0, 0, 0]], jnp.int32)
    s = st.overwrite_clean(s, keys, vals, jnp.asarray([2, 1]), jnp.ones(2, bool))
    assert int(s.values[1, 0, 0]) == 5 and int(s.seqs[1, 0]) == 2
