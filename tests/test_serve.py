"""Serving engine: batched prefill+decode waves, latency accounting,
greedy decoding sanity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import api
from repro.serve.engine import Request, ServingEngine, build_decode_step, \
    build_prefill_step

KEY = jax.random.PRNGKey(0)


def engine_for(arch_id="qwen1.5-0.5b", slots=4):
    cfg = dataclasses.replace(get_config(arch_id).reduced(), n_layers=2)
    params = api.init_params(cfg, KEY)
    return cfg, ServingEngine(cfg, params, slots=slots, cache_len=64)


def test_serving_engine_completes_requests():
    rng = np.random.default_rng(0)
    cfg, eng = engine_for()
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 16), max_new=6)
        for i in range(10)
    ]
    done = eng.run(reqs, prompt_len=8)
    assert len(done) == 10
    for r in done:
        assert r.output is not None and len(r.output) == 6
        assert (r.output >= 0).all() and (r.output < cfg.vocab_padded).all()
    assert len(eng.latencies_ms) == 10
    assert all(l > 0 for l in eng.latencies_ms)


def test_decode_steps_are_deterministic():
    cfg, eng = engine_for()
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 16)
    r1 = eng.run([Request(rid=0, prompt=prompt, max_new=8)], prompt_len=8)[0]
    r2 = eng.run([Request(rid=1, prompt=prompt, max_new=8)], prompt_len=8)[0]
    np.testing.assert_array_equal(r1.output, r2.output)


def test_prefill_and_decode_step_builders():
    cfg = dataclasses.replace(get_config("mamba2-1.3b").reduced(), n_layers=2)
    params = api.init_params(cfg, KEY)
    pf = jax.jit(build_prefill_step(cfg, cache_len=32))
    df = jax.jit(build_decode_step(cfg))
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab, jnp.int32)
    tok, cache = pf(params, {"tokens": toks})
    assert tok.shape == (2, 1)
    for _ in range(4):
        tok, cache = df(params, cache, tok)
    assert tok.shape == (2, 1)
    assert int(cache["t"]) == 8 + 4


def test_greedy_decode_reproduces_forced_sequence():
    """Feed the argmax back manually; engine must match step-by-step."""
    cfg, eng = engine_for()
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, 8)
    out = eng.run([Request(rid=0, prompt=prompt, max_new=4)], prompt_len=8)[0]
    params = eng.params
    batch = {"tokens": jnp.asarray(prompt[None, :8], jnp.int32)}
    logits, cache = api.prefill_fn(cfg)(params, batch, 64)
    toks = [int(jnp.argmax(logits[:, -1], -1)[0])]
    tok = jnp.asarray([[toks[0]]], jnp.int32)
    for _ in range(3):
        logits, cache = api.decode_fn(cfg)(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(int(tok[0, 0]))
    np.testing.assert_array_equal(out.output, np.asarray(toks))
